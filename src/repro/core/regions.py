"""Offloadable regions — the TPU analogue of the paper's "loop statements".

The paper enumerates loop statements of a C program and generates, per loop,
an OpenCL kernel/host split.  Here a *region* is a named compute function with
one or more *variants*:

* ``ref``     — the loop-faithful / plain-XLA implementation (the "CPU host"
                side; always present, used as the oracle),
* ``offload`` — the restructured high-performance implementation (vectorized /
                fused — what the Pallas kernel computes), timeable on any
                backend,
* ``pallas``  — the Pallas TPU kernel itself (validated with interpret=True
                on CPU; the deploy target on real hardware).

An *offload pattern* (paper §3.3) is a mapping ``{region -> gene}``; the
planner searches over patterns.  A gene is either a bare variant name
(``"pallas"``) or a ``(variant, params)`` pair carrying tile parameters —
the paper resizes the offloaded loop itself (unroll factor ``b``, pipeline
clauses) to fit the device, and a variant that wants the planner to search
its tile knobs declares a :class:`TuningSpace` next to its registration.

Canonicalization rule: params equal to the declared defaults are dropped,
so ``{r: ("pallas", {"block_n": 512})}`` (512 the default) and
``{r: "pallas"}`` are the *same gene* — same hash, same ledger entry, same
plan-cache identity.  Pre-tuning cache entries (bare strings) therefore
stay readable unchanged.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Optional

REGISTRY: dict[str, dict[str, Callable]] = {}

# (region, variant) -> TuningSpace for variants that declared tile knobs
_TUNING: dict[tuple[str, str], "TuningSpace"] = {}

# bumped on every registration (including re-registration under an existing
# name): anything that memoizes compiled artifacts of variant code — the
# verification executor's CompileCache — keys on this so swapping a
# variant's implementation can never serve a stale executable
_REGISTRY_VERSION = [0]


def registry_version() -> int:
    """Monotonic counter of variant (re-)registrations."""
    return _REGISTRY_VERSION[0]


@dataclass(frozen=True, init=False)
class TuningSpace:
    """Discrete tile-parameter space of one kernel variant.

    Declared next to the variant's registration
    (``register_variant(region, variant, tuning=TuningSpace(...))``) so
    the planner can widen the genome from ``{region -> variant}`` to
    ``{region -> (variant, params)}`` — the paper's loop-resizing knobs
    (unroll ``b``, tile sizes) made first-class search genes.

    Parameters
    ----------
    axes:
        ``{name: ordered value tuple}`` (or an iterable of pairs).  The
        order within an axis defines the tuner's neighbor steps.
    defaults:
        Per-axis default value (missing axes default to their first
        value).  MUST match the variant function's own keyword defaults:
        a gene whose params equal the defaults canonicalizes to the bare
        variant, so defaulted and bare genes share one identity.
    validity:
        Optional predicate ``fn(full_params: dict, args) -> bool`` ruling
        points in/out for the region's abstract ``args`` (shape
        divisibility, VMEM footprint).  ``args`` may be ``None`` for
        unbound queries.  Legality lives HERE, in one place — kernels
        clamp rather than assert, so any proposed point still runs.
    """
    axes: tuple
    defaults: tuple
    validity: Optional[Callable] = None

    def __init__(self, axes, defaults=None, validity=None):
        pairs = axes.items() if isinstance(axes, dict) else axes
        ax = tuple((str(name), tuple(vals)) for name, vals in pairs)
        dmap = dict(defaults or {})
        dflt = tuple((name, dmap.get(name, vals[0])) for name, vals in ax)
        object.__setattr__(self, "axes", ax)
        object.__setattr__(self, "defaults", dflt)
        object.__setattr__(self, "validity", validity)

    # -- basic views ---------------------------------------------------
    def names(self) -> tuple:
        return tuple(name for name, _ in self.axes)

    def default_params(self) -> dict:
        return dict(self.defaults)

    def full(self, params) -> dict:
        """Defaults overlaid with the known axes of ``params``."""
        p = self.default_params()
        for k, v in dict(params or {}).items():
            if k in p:
                p[k] = v
        return p

    def canonical(self, params) -> tuple:
        """The non-default entries of ``params`` as ``((name, value), ...)``
        in declared axis order — empty exactly when the point IS the
        default, which is what collapses defaulted genes onto bare ones."""
        d = self.default_params()
        p = dict(params or {})
        return tuple((name, p[name]) for name, _ in self.axes
                     if name in p and p[name] != d[name])

    # -- legality ------------------------------------------------------
    def is_valid(self, params, args=None) -> bool:
        p = self.full(params)
        for name, vals in self.axes:
            if p[name] not in vals:
                return False
        if self.validity is not None:
            try:
                return bool(self.validity(p, args))
            except Exception:  # noqa: BLE001 — an erroring predicate = invalid
                return False
        return True

    def points(self, args=None) -> list[dict]:
        """Every valid full-param point, deterministic (product) order."""
        names = self.names()
        out = []
        for combo in itertools.product(*(vals for _, vals in self.axes)):
            p = dict(zip(names, combo))
            if self.is_valid(p, args):
                out.append(p)
        return out

    def size(self, args=None) -> int:
        return len(self.points(args))

    def neighbors(self, params, args=None) -> list[dict]:
        """Valid one-axis ±1 steps (within each axis's declared order)
        around ``params`` — the tuner's neighbor-step mutation moves."""
        p = self.full(params)
        out = []
        for name, vals in self.axes:
            try:
                i = vals.index(p[name])
            except ValueError:
                i = 0
            for j in (i - 1, i + 1):
                if 0 <= j < len(vals):
                    q = dict(p)
                    q[name] = vals[j]
                    if self.is_valid(q, args):
                        out.append(q)
        return out

    def signature(self) -> list:
        """JSON-safe identity for plan-cache keys: axes, values, defaults
        (the validity code deliberately excluded — tightening a predicate
        prunes points but does not invalidate measured ones)."""
        d = self.default_params()
        return [[name, list(vals), d[name]] for name, vals in self.axes]


@dataclass(frozen=True)
class BoundTuningSpace:
    """A :class:`TuningSpace` closed over a region's abstract args, so
    search strategies can enumerate/step points without carrying shapes."""
    space: TuningSpace
    args: tuple = ()

    def default_params(self) -> dict:
        return self.space.default_params()

    def canonical(self, params) -> tuple:
        return self.space.canonical(params)

    def full(self, params) -> dict:
        return self.space.full(params)

    def is_valid(self, params) -> bool:
        return self.space.is_valid(params, self.args)

    def points(self) -> list[dict]:
        return self.space.points(self.args)

    def size(self) -> int:
        return self.space.size(self.args)

    def neighbors(self, params) -> list[dict]:
        return self.space.neighbors(params, self.args)


def register_variant(region: str, variant: str,
                     tuning: TuningSpace | None = None) -> Callable:
    def deco(fn: Callable) -> Callable:
        REGISTRY.setdefault(region, {})[variant] = fn
        if tuning is not None:
            _TUNING[(region, variant)] = tuning
        _REGISTRY_VERSION[0] += 1
        return fn
    return deco


def unregister_variant(region: str, variant: str) -> bool:
    """Remove one variant registration (and its TuningSpace).  Bumps the
    registry version just like registration: a CompileCache keyed on the
    old registry must never serve its executable after the variant is gone.
    Primarily for tests/benchmarks that register throwaway variants on real
    regions and must not pollute later searches; returns whether the
    variant existed."""
    table = REGISTRY.get(region)
    existed = table is not None and table.pop(variant, None) is not None
    if table is not None and not table:
        REGISTRY.pop(region, None)
    _TUNING.pop((region, variant), None)
    if existed:
        _REGISTRY_VERSION[0] += 1
    return existed


def tuning_space(region: str, variant: str) -> Optional[TuningSpace]:
    """The TuningSpace a variant declared at registration, or None."""
    return _TUNING.get((region, variant))


def variants(region: str) -> dict[str, Callable]:
    return dict(REGISTRY.get(region, {}))


def offload_variants(region: str) -> dict[str, Callable]:
    """Every registered non-ref variant — the destinations the mixed-pattern
    planner searches over (``ref`` is the host side, never an offload)."""
    return {v: fn for v, fn in REGISTRY.get(region, {}).items() if v != "ref"}


def region_names() -> list[str]:
    return sorted(REGISTRY)


# ---------------------------------------------------------------------------
# Genes: bare variant names or (variant, params) pairs
# ---------------------------------------------------------------------------
def split_gene(value) -> tuple[str, dict]:
    """``(variant, params)`` view of one Impl gene value.  Accepts the bare
    variant string, a ``(variant, params_dict)`` pair, or the JSON
    round-trip forms (lists; params as a list of ``[name, value]`` pairs)
    — plan-cache entries written before tile genes existed parse as bare
    variants with empty params."""
    if isinstance(value, str):
        return value, {}
    if isinstance(value, (tuple, list)) and len(value) == 2:
        name, params = value
        if isinstance(params, dict):
            return str(name), dict(params)
        try:
            return str(name), {str(k): v for k, v in params}
        except (TypeError, ValueError):
            return str(name), {}
    return str(value), {}


def gene_variant(value) -> str:
    """The variant name of a gene value, params dropped."""
    return split_gene(value)[0]


def canonical_gene(region: str, value):
    """Canonical gene value: the bare variant string when the params equal
    the variant's declared defaults (or it declared no TuningSpace), else
    ``(variant, ((name, value), ...))`` with only the non-default entries.
    This single rule makes defaulted-param genes hash/dedup identically to
    bare ones everywhere (ledger, compile cache, plan cache)."""
    name, params = split_gene(value)
    if not params:
        return name
    space = _TUNING.get((region, name))
    if space is None:
        return name
    canon = space.canonical(params)
    return name if not canon else (name, canon)


class Impl(dict):
    """A chosen offload pattern: region name -> gene (default 'ref').

    A gene is a bare variant name or a ``(variant, params)`` pair (see
    :func:`split_gene`); ``pick`` keeps returning the variant *name* for
    callers that only route, ``gene`` returns the full (variant, params)
    view the dispatcher and the tuner use."""

    def pick(self, region: str) -> str:
        return gene_variant(self.get(region, "ref"))

    def gene(self, region: str) -> tuple[str, dict]:
        return split_gene(self.get(region, "ref"))

    def describe(self) -> str:
        parts = []
        for r in sorted(self):
            g = canonical_gene(r, self[r])
            name, params = split_gene(g)
            if name == "ref":
                continue
            if params:
                inner = ",".join(f"{k}={v}" for k, v in sorted(params.items()))
                parts.append(f"{r}={name}[{inner}]")
            else:
                parts.append(f"{r}={name}")
        return "+".join(parts) or "all-ref"


def dispatch(region: str, impl: Optional[Impl], *args, **kwargs):
    choice, params = impl.gene(region) if impl else ("ref", {})
    table = REGISTRY.get(region)
    if table is None:
        raise KeyError(f"unknown region {region!r}")
    if choice not in table:
        raise KeyError(f"region {region!r} has no variant {choice!r}; has {sorted(table)}")
    if params:
        # gene params are the variant's configuration: they win over caller
        # kwargs, and only the declared tuning axes pass through
        space = _TUNING.get((region, choice))
        if space is not None:
            known = set(space.names())
            params = {k: v for k, v in params.items() if k in known}
        kwargs = {**kwargs, **params}
    return table[choice](*args, **kwargs)
