"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 (paper-table).

[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (GQA kv=8) moe_d_ff=2048
vocab=163840, MoE 384e top-8.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,            # assignment lists d_ff=2048 (per-expert width)
    vocab_size=163_840,
    num_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    capacity_factor=1.25,
    rope_theta=50_000.0,
    source="arXiv:2501.kimi2; unverified",
))
