"""Paper §5.1.2 evaluation-conditions table reproduction + recognizer
accuracy for the static extractor.

The paper reports, per app: loop statements found (tdFIR 36, MRI-Q 16),
arithmetic-intensity narrowing to top-5, resource-efficiency narrowing to
top-3, and <= 4 measured offload patterns.  This benchmark runs our Step 1-4
pipeline and emits the same table: the stage widths must match the paper's
budgets exactly (they are the planner's defaults).

The ``extraction`` section scores ``core/extract.py`` against the
hand-annotated programs: the families ``make_lm_program(arch)`` /
``tdfir.make_program`` register by hand are the ground truth (plus
``rmsnorm``, which every LM arch contains), and the recognizers must reach
0.9 precision AND 0.9 recall both micro-averaged and **per family** across
all nine families — a newly added family at 0.0 recall fails CI even when
the aggregate still clears the gate.  Stitched ``left+right`` fusion
regions sit outside the scored universe (they are derived, not annotated).
It then proves the point of static extraction end to end: ``discover`` +
``AutoOffloader.plan`` on whisper-small and paligemma-3b — two programs
nobody annotated — must find >= 2 regions each, plan, and hit the plan
cache on re-plan; and the stitch demo plans whisper's fused
``rmsnorm+mlp_gelu`` region against its split form, proving the fused
variant is measured first-class and re-keys the plan cache.

With ``--json PATH`` the rows are also written as a BENCH_*.json document so
CI can archive them as an artifact.  ``--explain`` additionally prints each
program's full extraction summary including the structured rejection
diagnostics (near-miss reasons).

Run:  PYTHONPATH=src python -m benchmarks.loop_extraction [--json PATH]
      PYTHONPATH=src python -m benchmarks.loop_extraction --extraction
      PYTHONPATH=src python -m benchmarks.loop_extraction --extraction --explain
"""
from __future__ import annotations

import argparse
import json
import tempfile

import jax

from repro.apps import mriq, tdfir
from repro.core.planner import AutoOffloader, PlannerConfig


def run(reps: int = 2) -> list[dict]:
    rows = []
    for name, make in (("tdfir", tdfir.make_program), ("mriq", mriq.make_program)):
        prog = make()
        rep = AutoOffloader(PlannerConfig(reps=reps)).plan(prog,
                                                           jax.random.PRNGKey(0))
        rows.append({
            "app": name,
            "source_loops": rep.source_loop_count,
            "jaxpr_loops": rep.jaxpr_loop_count,
            "regions": len(rep.candidates),
            "after_ai": len(rep.ai_selected),
            "after_eff": len(rep.eff_selected),
            "measured": len(rep.measurements),
            "strategy": rep.strategy,
            "speedup": rep.speedup,
        })
    return rows


# --- recognizer accuracy vs the hand-annotated programs -----------------

# the scored universe: every recognizable kernel family.  Stitched
# "left+right" fusion regions are derived from base matches, not annotated,
# so they stay outside the scorable claims.
from repro.core.extract import FAMILIES  # noqa: E402

UNIVERSE = frozenset(FAMILIES)
# the archs whose annotated path (make_lm_program) is the ground truth;
# mixtral exercises moe_dispatch, whisper mlp_gelu + conv_stem
GROUND_TRUTH_ARCHS = ("mistral-nemo-12b", "phi3-medium-14b", "qwen2-72b",
                      "deepseek-67b", "recurrentgemma-2b", "falcon-mamba-7b",
                      "mixtral-8x7b", "whisper-small")
# programs with NO annotated path at all — the extraction's reason to exist
UNANNOTATED_ARCHS = ("whisper-small", "paligemma-3b", "mixtral-8x7b")


def _trace_arch(arch: str, seq: int = 32):
    """(callable, concrete args) for an arch's all-ref reduced forward."""
    from repro.configs import get_config
    from repro.core.regions import Impl
    from repro.models import factory as F

    cfg = get_config(arch).reduced()
    params = F.init_params(cfg, jax.random.PRNGKey(0))
    batch = F.synthetic_batch(cfg, 1, seq, jax.random.PRNGKey(1))
    kw = {k: v for k, v in batch.items() if k != "tokens"}
    fwd = F.make_forward(cfg, Impl())
    return (lambda t: fwd(params, {"tokens": t, **kw})), (batch["tokens"],)


def _ground_truth_cases(seq: int = 32):
    """(name, callable, args, annotated-family set) per scored program."""
    from repro.apps import tdfir
    from repro.configs.paper_apps import TdFirConfig
    from repro.core.regions import Impl
    from repro.models.offload_program import make_lm_program

    cases = []
    for arch in GROUND_TRUTH_ARCHS:
        f, args = _trace_arch(arch, seq=seq)
        annotated = {r.name for r in make_lm_program(arch).regions} & UNIVERSE
        # every LM arch normalizes with rms_norm blocks; the annotated path
        # doesn't register them as regions (the models call the layer
        # directly) but their presence in the trace is ground truth
        annotated.add("rmsnorm")
        cases.append((arch, f, args, annotated))
    # tdfir exercises fir_bank (the paper's app #1)
    cfg = TdFirConfig(n_banks=4, n_taps=16, n_samples=256)
    prog = tdfir.make_program(cfg, cfg)
    annotated = {r.name for r in prog.regions} & UNIVERSE
    cases.append(("tdfir", prog.build(Impl()),
                  prog.sample_inputs(jax.random.PRNGKey(0)), annotated))
    return cases


def run_accuracy(seq: int = 32, explain: bool = False
                 ) -> tuple[list[dict], float, float, dict]:
    """Per-program recognizer hits vs annotation; micro AND per-family
    precision/recall."""
    from repro.core.extract import extract

    rows = []
    fam = {f: {"tp": 0, "fp": 0, "fn": 0} for f in sorted(UNIVERSE)}
    for name, f, args, annotated in _ground_truth_cases(seq=seq):
        report = extract(f, args, name=name)
        found = {m.family for m in report.legal_matches}
        claimed = found & UNIVERSE
        for fa in claimed & annotated:
            fam[fa]["tp"] += 1
        for fa in claimed - annotated:
            fam[fa]["fp"] += 1
        for fa in annotated - claimed:
            fam[fa]["fn"] += 1
        rows.append({
            "app": name,
            "annotated": ",".join(sorted(annotated)),
            "discovered": ",".join(sorted(claimed)),
            "beyond_annotation": ",".join(sorted(found - UNIVERSE)),
            "tp": len(claimed & annotated),
            "fp": len(claimed - annotated),
            "fn": len(annotated - claimed),
            "rejections": len(report.rejections),
        })
        if explain:
            print(f"--- {name} ---")
            print(report.summary())
    tp = sum(s["tp"] for s in fam.values())
    fp = sum(s["fp"] for s in fam.values())
    fn = sum(s["fn"] for s in fam.values())
    per_family = {
        f: {**s,
            "precision": s["tp"] / (s["tp"] + s["fp"])
            if s["tp"] + s["fp"] else 1.0,
            "recall": s["tp"] / (s["tp"] + s["fn"])
            if s["tp"] + s["fn"] else 1.0}
        for f, s in fam.items()}
    precision = tp / (tp + fp) if tp + fp else 1.0
    recall = tp / (tp + fn) if tp + fn else 1.0
    return rows, precision, recall, per_family


def run_autoplan(reps: int = 1, seq: int = 32,
                 cache_dir: str | None = None) -> list[dict]:
    """discover() + plan + cached re-plan on the unannotated programs."""
    from repro.core.extract import discover

    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        cache = f"{cache_dir or tmp}/plans.json"
        for arch in UNANNOTATED_ARCHS:
            f, args = _trace_arch(arch, seq=seq)
            prog = discover(f, args, name=arch)
            planner = AutoOffloader(PlannerConfig(
                max_measurements=3, reps=reps, warmup=0))
            first = planner.plan(prog, jax.random.PRNGKey(0), cache=cache)
            replan = planner.plan(prog, jax.random.PRNGKey(0), cache=cache)
            rows.append({
                "app": arch,
                "regions": len(prog.regions),
                "families": ",".join(sorted(r.name for r in prog.regions)),
                "best_pattern": dict(first.best_pattern or {}),
                "plan_speedup": first.speedup,
                "measured": len(first.measurements),
                "cached_replan": bool(replan.from_cache),
            })
    return rows


def run_stitch_demo(reps: int = 1, seq: int = 32) -> dict:
    """Plan whisper's fused ``rmsnorm+mlp_gelu`` region against its split
    form: the stitched region must be proposed and measured first-class,
    and its presence must re-key the plan cache."""
    from repro.core.extract import discover
    from repro.core.plan_cache import plan_cache_key

    f, args = _trace_arch("whisper-small", seq=seq)
    fused_fams = ("rmsnorm", "mlp_gelu", "rmsnorm+mlp_gelu")
    prog = discover(f, args, name="whisper-stitch", families=fused_fams)
    fused = sorted(r.name for r in prog.regions if "+" in r.name)
    assert fused, "no stitched region discovered on whisper-small"
    cfg = PlannerConfig(max_measurements=6, reps=reps, warmup=0,
                        strategy="staged")
    rep = AutoOffloader(cfg).plan(prog, jax.random.PRNGKey(0))
    measured = {g for m in rep.measurements for g in (m.mapping() or {})}
    assert fused[0] in measured, \
        f"stitched region {fused[0]} never measured (got {sorted(measured)})"
    assert measured & set(fused[0].split("+")), \
        "split form never measured against the stitched region"
    # fused regions are first-class in the plan-cache key: the same program
    # extracted without stitching keys differently
    split_prog = discover(f, args, name="whisper-stitch",
                          families=("rmsnorm", "mlp_gelu"))
    key_fused = plan_cache_key(prog, cfg)
    key_split = plan_cache_key(split_prog, cfg)
    assert key_fused != key_split, \
        "fused/split region choice not reflected in the plan-cache key"
    return {
        "app": "whisper-stitch",
        "fused_regions": ",".join(fused),
        "measured_genes": ",".join(sorted(measured)),
        "best_pattern": dict(rep.best_pattern or {}),
        "fused_key": key_fused,
        "split_key": key_split,
        "search_trace_stages": len(rep.search_trace),
    }


def main_extraction(json_path: str | None = None, reps: int = 1,
                    seq: int = 32, explain: bool = False) -> dict:
    acc_rows, precision, recall, per_family = run_accuracy(seq=seq,
                                                           explain=explain)
    print("app,annotated,discovered,beyond_annotation,tp,fp,fn,rejections")
    for r in acc_rows:
        print(f"{r['app']},{r['annotated']},{r['discovered']},"
              f"{r['beyond_annotation']},{r['tp']},{r['fp']},{r['fn']},"
              f"{r['rejections']}")
    print(f"micro_precision={precision:.3f} micro_recall={recall:.3f}")
    print("family,tp,fp,fn,precision,recall")
    for fa, s in sorted(per_family.items()):
        print(f"{fa},{s['tp']},{s['fp']},{s['fn']},"
              f"{s['precision']:.3f},{s['recall']:.3f}")
    assert precision >= 0.9, f"recognizer precision {precision:.3f} < 0.9"
    assert recall >= 0.9, f"recognizer recall {recall:.3f} < 0.9"
    for fa, s in per_family.items():
        # a family nothing in the ground truth exercises would pass any
        # gate vacuously — that's a benchmark hole, fail loudly
        assert s["tp"] + s["fn"] > 0, f"no ground-truth program contains {fa}"
        assert s["recall"] >= 0.9, \
            f"{fa}: recall {s['recall']:.3f} < 0.9"
        assert s["precision"] >= 0.9, \
            f"{fa}: precision {s['precision']:.3f} < 0.9"

    plan_rows = run_autoplan(reps=reps, seq=seq)
    print("app,regions,families,plan_speedup,measured,cached_replan")
    for r in plan_rows:
        print(f"{r['app']},{r['regions']},{r['families']},"
              f"{r['plan_speedup']:.2f},{r['measured']},{r['cached_replan']}")
        assert r["regions"] >= 2, \
            f"{r['app']}: expected >= 2 discovered regions, got {r['regions']}"
        assert r["cached_replan"], f"{r['app']}: re-plan missed the plan cache"
    # the MoE arch must auto-plan with its routed block as a region
    moe_row = next(r for r in plan_rows if r["app"] == "mixtral-8x7b")
    assert "moe_dispatch" in moe_row["families"], \
        f"mixtral auto-plan lost moe_dispatch: {moe_row['families']}"

    stitch_row = run_stitch_demo(reps=reps, seq=seq)
    print(f"stitch: fused={stitch_row['fused_regions']} "
          f"measured={stitch_row['measured_genes']} "
          f"best={stitch_row['best_pattern']}")

    doc = {"section": "extraction",
           "backend": jax.default_backend(),
           "precision": precision, "recall": recall,
           "per_family": per_family,
           "stitch": stitch_row,
           "rows": acc_rows + plan_rows}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    return doc


def main(json_path: str | None = None, reps: int = 2) -> list[dict]:
    rows = run(reps=reps)
    print("app,source_loops,jaxpr_loops,regions,after_ai(a<=5),"
          "after_eff(c<=3),measured(d<=4)")
    for r in rows:
        print(f"{r['app']},{r['source_loops']},{r['jaxpr_loops']},"
              f"{r['regions']},{r['after_ai']},{r['after_eff']},"
              f"{r['measured']}")
        assert r["after_ai"] <= 5
        assert r["after_eff"] <= 3
        assert r["measured"] <= 4
    if json_path:
        doc = {"section": "conditions",
               "backend": jax.default_backend(),
               "rows": rows}
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_*.json-style output here")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--extraction", action="store_true",
                    help="run the recognizer precision/recall + unannotated "
                         "auto-plan section instead of the conditions table")
    ap.add_argument("--explain", action="store_true",
                    help="with --extraction: print each program's full "
                         "extraction summary incl. rejection diagnostics")
    a = ap.parse_args()
    if a.extraction:
        main_extraction(json_path=a.json, reps=min(a.reps, 2),
                        explain=a.explain)
    else:
        main(json_path=a.json, reps=a.reps)
