"""Mamba-1 selective-state-space block (falcon-mamba-7b).

Recurrence per channel c and state n:
    h_t = exp(dt_t * A[c,n]) * h_{t-1} + dt_t * B_t[n] * x_t[c]
    y_t = sum_n C_t[n] * h_t[c,n] + D[c] * x_t[c]

Training/prefill uses a *chunked associative scan*: ``lax.scan`` over chunks
of the sequence carrying h, with ``lax.associative_scan`` inside each chunk.
This bounds the [B, chunk, D, N] working set (the full [B, S, D, N] tensor at
S=4k, D=8k, N=16 would be >1 TB fp32 per pod) while keeping O(log chunk)
sequential depth inside the chunk.  TPU adaptation note: on FPGA the paper's
offload target is the loop nest; here the offload target is this scan region,
and the Pallas kernel (`kernels/ssm_scan.py`) tiles channels into VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.regions import register_variant


# ---------------------------------------------------------------------------
# Depthwise causal conv (kernel size K, shift-and-add formulation)
# ---------------------------------------------------------------------------
def causal_depthwise_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None,
                          length: jax.Array | None = None):
    """x: [B, S, D]; w: [K, D]; state: [B, K-1, D] trailing context or None.

    ``length`` (traced scalar): only the first ``length`` positions of x are
    real — the returned state is then the K-1 inputs *ending at* position
    ``length`` (bucketed prefill right-pads x, and the trailing context must
    not contain padding).  None = all S positions are real.

    Returns (y [B, S, D], new_state [B, K-1, D])."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                  # [B, S+K-1, D]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    if k <= 1:
        new_state = jnp.zeros_like(state)
    elif length is None:
        new_state = xp[:, -(k - 1):]
    else:
        # inputs at positions [length-(K-1), length) = xp[length : length+K-1]
        new_state = jax.lax.dynamic_slice_in_dim(xp, length, k - 1, axis=1)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Selective scan (region: "ssm_scan")
# ---------------------------------------------------------------------------
def _assoc_combine(l, r):
    a_l, b_l = l
    a_r, b_r = r
    return a_l * a_r, b_l * a_r + b_r


@register_variant("ssm_scan", "ref")
def ssm_scan_ref(a: jax.Array, bx: jax.Array, c: jax.Array, h0: jax.Array,
                 chunk: int = 256):
    """a, bx: [B, S, D, N] (decay and input); c: [B, S, N]; h0: [B, D, N].

    Returns (y [B, S, D], h_final [B, D, N])."""
    b, s, d, n = a.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    a = a.reshape(b, nc, chunk, d, n)
    bx = bx.reshape(b, nc, chunk, d, n)
    c = c.reshape(b, nc, chunk, n)

    def chunk_body(h, inp):
        a_c, bx_c, c_c = inp                                   # [B, chunk, D, N]
        cum_a, cum_b = jax.lax.associative_scan(_assoc_combine, (a_c, bx_c), axis=1)
        h_t = cum_a * h[:, None] + cum_b                       # [B, chunk, D, N]
        y_c = jnp.einsum("btdn,btn->btd", h_t, c_c)
        return h_t[:, -1], y_c

    # scan over chunks: move chunk axis first
    a_s = jnp.moveaxis(a, 1, 0)
    bx_s = jnp.moveaxis(bx, 1, 0)
    c_s = jnp.moveaxis(c, 1, 0)
    h_f, ys = jax.lax.scan(chunk_body, h0.astype(a.dtype), (a_s, bx_s, c_s))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, d)[:, :s]
    return y, h_f


@register_variant("ssm_scan", "offload")
def ssm_scan_offload(a, bx, c, h0, chunk: int = 512):
    """Same math, larger chunks + fp32 state accumulation (the restructuring
    the Pallas kernel implements: fewer carries, MXU-aligned einsum)."""
    return ssm_scan_ref(a.astype(jnp.float32), bx.astype(jnp.float32),
                        c.astype(jnp.float32), h0, chunk=chunk)


@register_variant("ssm_scan", "seq")
def ssm_scan_seq_chunked(a, bx, c, h0, chunk: int = 256):
    """Time-SEQUENTIAL chunked scan — the Pallas kernel's schedule in XLA.

    The associative-scan formulation streams O(S log chunk) bytes of
    slice/concat intermediates per level; this variant reads each element
    exactly once per pass (perf iteration 'falcon-mamba A1', EXPERIMENTS.md
    §Perf).  Outer scan carries h across chunks (checkpointed, so backward
    recomputes within-chunk states from the chunk-boundary h instead of
    storing [B, S, D, N] residuals)."""
    b, s, d, n = a.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    a_s = jnp.moveaxis(a.reshape(b, nc, chunk, d, n), 1, 0)
    bx_s = jnp.moveaxis(bx.reshape(b, nc, chunk, d, n), 1, 0)
    c_s = jnp.moveaxis(c.reshape(b, nc, chunk, n), 1, 0)

    @jax.checkpoint
    def chunk_body(h, inp):
        a_c, bx_c, c_c = inp                       # [B, chunk, D, N]

        def step(hh, t_inp):
            a_t, bx_t, c_t = t_inp                 # [B, D, N], [B, N]
            hh = a_t * hh + bx_t
            y_t = jnp.einsum("bdn,bn->bd", hh, c_t)
            return hh, y_t

        h, ys = jax.lax.scan(step, h,
                             (jnp.moveaxis(a_c, 1, 0),
                              jnp.moveaxis(bx_c, 1, 0),
                              jnp.moveaxis(c_c, 1, 0)))
        return h, jnp.moveaxis(ys, 0, 1)           # [B, chunk, D]

    h_f, ys = jax.lax.scan(chunk_body, h0.astype(a.dtype), (a_s, bx_s, c_s))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, d)[:, :s]
    return y, h_f


def ssm_decode_step(a, bx, c, h):
    """Single-token recurrence.  a, bx: [B, D, N]; c: [B, N]; h: [B, D, N]."""
    h_new = a * h + bx
    y = jnp.einsum("bdn,bn->bd", h_new, c)
    return y, h_new


# ---------------------------------------------------------------------------
# Full Mamba block
# ---------------------------------------------------------------------------
def mamba_block(params, x, *, cfg, impl=None, state=None, length=None):
    """x: [B, S, D_model].  state: None (train) or dict(conv, h) for decode-
    style stateful prefill.  ``length`` (traced scalar): positions >= length
    are right-padding — their recurrence steps are masked to the identity
    (a=1, bx=0) so the final state is exactly the state after ``length`` real
    tokens (bucketed prefill).  Returns (y, new_state)."""
    from repro.core.regions import dispatch

    b, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ params["w_in"]                                    # [B, S, 2*Di]
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xi, new_conv = causal_depthwise_conv(xi, params["conv_w"], conv_state,
                                         length=length)
    xi = jax.nn.silu(xi)

    # input-dependent dt, B, C
    dbc = xi @ params["w_dbc"]                                 # [B, S, dt_rank + 2N]
    dtr = cfg.resolved_dt_rank
    dt, bmat, cmat = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt @ params["w_dt"] + params["dt_bias"])   # [B, S, Di]
    a_log = -jnp.exp(params["a_log"].astype(jnp.float32))      # [Di, N]

    a = jnp.exp(dt[..., None].astype(jnp.float32) * a_log)     # [B, S, Di, N]
    bx = (dt * xi)[..., None] * bmat[:, :, None, :]            # [B, S, Di, N]
    if length is not None:
        pad = (jnp.arange(s) >= length)[None, :, None, None]
        a = jnp.where(pad, 1.0, a)
        bx = jnp.where(pad, 0.0, bx)
    from repro.parallel.ctx import constrain
    a = constrain(a, ("batch", None, "inner", None))
    bx = constrain(bx, ("batch", None, "inner", None))
    h0 = (jnp.zeros((b, di, n), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))
    y, h_f = dispatch("ssm_scan", impl, a.astype(x.dtype), bx.astype(x.dtype),
                      cmat.astype(x.dtype), h0)
    y = y + xi * params["d_skip"]
    y = y * jax.nn.silu(z)
    out = y @ params["w_out"]
    new_state = {"conv": new_conv, "h": h_f.astype(jnp.float32)}
    return out.astype(x.dtype), new_state


def mamba_decode_step(params, x, state, *, cfg, impl=None):
    """x: [B, 1, D_model]; state: dict(conv [B, K-1, Di], h [B, Di, N])."""
    b = x.shape[0]
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ params["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                          # [B, 1, Di]
    xi, new_conv = causal_depthwise_conv(xi, params["conv_w"], state["conv"])
    xi = jax.nn.silu(xi)

    dbc = xi @ params["w_dbc"]
    dtr = cfg.resolved_dt_rank
    dt, bmat, cmat = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt @ params["w_dt"] + params["dt_bias"])
    a_log = -jnp.exp(params["a_log"].astype(jnp.float32))

    a = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * a_log)     # [B, Di, N]
    bx = (dt * xi)[:, 0, :, None] * bmat[:, 0, None, :]        # [B, Di, N]
    y, h_new = ssm_decode_step(a.astype(jnp.float32), bx.astype(jnp.float32),
                               cmat[:, 0].astype(jnp.float32), state["h"])
    y = y[:, None, :].astype(x.dtype) + xi * params["d_skip"]
    y = y * jax.nn.silu(z)
    out = y @ params["w_out"]
    return out.astype(x.dtype), {"conv": new_conv, "h": h_new}
