"""Resource estimation — the paper's Step 3 (HDL-stage precompile analogue).

On FPGA: generate per-loop OpenCL, compile *only to the HDL stage* (minutes),
read Flip-Flop/LUT utilization.  On TPU: lower the variant with
``jax.jit(...).lower()`` (seconds, no full compile), read

* ``vmem_bytes``   — the kernel's VMEM working set.  For Pallas variants this
  comes from the registered BlockSpec-tile estimator (the tiles ARE the VMEM
  claim); for XLA variants, from the largest live intermediate in the jaxpr
  (a fusion-tile proxy).
* ``hlo_ops``      — lowered StableHLO op count ("logic utilization" proxy).
* ``lower_seconds``— the precompile cost itself (recorded, like the paper's
  minutes-level HDL pass).

``resource_fraction`` = vmem_bytes / 16 MiB, the denominator of the paper's
resource efficiency.  Patterns whose summed fraction exceeds the cap are
never built (paper: combinations over the FPGA resource limit are skipped).

These Step-3 estimates do double duty: together with the Step-2 analysis
counts (flops / bytes / transcendentals / alignment) they seed the roofline
``CostModel`` (core/cost_model.py) that the ``surrogate`` search strategy
uses to score whole genome populations without spending measurements.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

VMEM_BUDGET = 16 * 1024 * 1024      # 16 MiB per TPU core

# (region, variant) -> fn(*abstract_args) -> vmem bytes.  Mirrors each
# kernel's BlockSpec tiling (documented in the kernel files).
_VMEM_ESTIMATORS: dict[tuple[str, str], Callable] = {}


def register_vmem_estimator(region: str, variant: str):
    def deco(fn):
        _VMEM_ESTIMATORS[(region, variant)] = fn
        return fn
    return deco


def _default_vmem_estimate(fn, args) -> float:
    """Largest live intermediate tensor in the jaxpr — proxy for the fusion
    tile an XLA variant would hold resident."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    biggest = 0

    def walk(j):
        nonlocal biggest
        for eqn in j.eqns:
            for v in eqn.outvars:
                if v.aval.shape:
                    biggest = max(biggest, int(np.prod(v.aval.shape))
                                  * jnp.dtype(v.aval.dtype).itemsize)
            if not hasattr(eqn, "params"):
                continue
            for p in ("jaxpr", "body_jaxpr", "call_jaxpr", "cond_jaxpr"):
                inner = eqn.params.get(p)
                if inner is not None:
                    walk(getattr(inner, "jaxpr", inner))
            # `cond` carries its arms in `branches`, not a single sub-jaxpr;
            # skipping them let conditional regions under-report VMEM
            for br in eqn.params.get("branches", ()) or ():
                walk(getattr(br, "jaxpr", br))
    walk(jaxpr.jaxpr)
    return float(min(biggest, 8 * VMEM_BUDGET))


@dataclass
class ResourceEstimate:
    region: str
    variant: str
    vmem_bytes: float
    hlo_ops: int
    lower_seconds: float
    lower_ok: bool
    error: str = ""

    @property
    def resource_fraction(self) -> float:
        """Fraction of the VMEM budget (>1.0 = spills, like FPGA overflow)."""
        return self.vmem_bytes / VMEM_BUDGET


def precompile(region: str, variant: str, fn: Callable, args,
               static_kwargs: Optional[dict] = None) -> ResourceEstimate:
    """The cheap lowering pass.  ``args`` may be ShapeDtypeStructs."""
    static_kwargs = static_kwargs or {}
    t0 = time.perf_counter()
    try:
        lowered = jax.jit(lambda *a: fn(*a, **static_kwargs)).lower(*args)
        text = lowered.as_text()
        hlo_ops = sum(1 for line in text.splitlines() if "=" in line)
        est = _VMEM_ESTIMATORS.get((region, variant))
        vmem = float(est(*args)) if est else _default_vmem_estimate(
            lambda *a: fn(*a, **static_kwargs), args)
        return ResourceEstimate(region, variant, vmem, hlo_ops,
                                time.perf_counter() - t0, True)
    except Exception as e:  # noqa: BLE001 — a failed lower = unusable variant
        return ResourceEstimate(region, variant, float("inf"), 0,
                                time.perf_counter() - t0, False, f"{type(e).__name__}: {e}")


def precompile_many(jobs, mapper=map) -> list[ResourceEstimate]:
    """Step-3 fan-out: lower many (region, variant) pairs at once.

    ``jobs`` are ``(region, variant, fn, args, static_kwargs)`` tuples;
    ``mapper`` is any order-preserving map — the planner passes
    ``VerificationExecutor.map_concurrent`` so the per-pair lowering calls
    (each independent, like the paper's per-loop HDL-stage compiles) run
    concurrently under ``verify_workers``.  Results come back in job order,
    so the efficiency ranking downstream is identical at any worker count.
    """
    return list(mapper(lambda j: precompile(*j), list(jobs)))


# ---------------------------------------------------------------------------
# VMEM estimators mirroring the kernels' BlockSpecs
# ---------------------------------------------------------------------------
@register_vmem_estimator("fir_bank", "pallas")
def _fir_vmem(x, h, *_):
    k = h.shape[-1]
    block_n = 512
    return 4.0 * (2 * (block_n + k - 1) + 2 * k + 2 * block_n)


@register_vmem_estimator("compute_q", "pallas")
def _mriq_vmem(x, *_):
    bx, bk = 256, 512
    return 4.0 * (bx * 4 + 4 * bk + 3 * bx * bk)


@register_vmem_estimator("attn_core", "pallas")
def _flash_vmem(q, k, v, *_):
    d = q.shape[-1]
    bq, bk = 256, 512
    return 4.0 * (bq * d + 2 * bk * d + bq * bk + 2 * bq * d)


@register_vmem_estimator("rglru_scan", "pallas")
def _rglru_vmem(a, b, h0, *_):
    bc, tc = 128, 128
    return 4.0 * (2 * tc * bc + 2 * bc + tc * bc)


@register_vmem_estimator("ssm_scan", "pallas")
def _ssm_vmem(a, bx, c, h0, *_):
    n = a.shape[-1]
    bc, tc = 128, 64
    return 4.0 * (2 * tc * bc * n + bc * n + tc * n + tc * bc)
