"""Deterministic serving invariant-test harness (ISSUE 9).

Scripted-traffic driver for ``ServeEngine`` tests: a seeded arrival process
over explicit phases (so drift — short→long prompts, bucket-mix shifts — is
scripted, not sampled at test time), plus the serving invariants every
engine run must hold:

* **conservation** — ``requests_submitted == requests_finished_total +
  requests_pending + requests_active`` in both stats views, at every tick;
* **no drops** — every submitted rid finishes, exactly once;
* **monotone rids** — ``submit()`` returns strictly increasing ids and
  ``run_to_completion``/``drain_finished`` return rid-sorted results;
* **stream equality** — per-request token streams bit-identical between two
  engines fed the same script (the hot-swap atomicity check compares a
  replanning engine against a never-swapped one).

Pure driver: no timing, no randomness beyond the seeded schedule (the full
schedule is precomputed in ``__init__``, so two ScriptedTraffic instances
with equal arguments submit byte-identical prompts on identical ticks).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Phase:
    """One traffic regime: for ``ticks`` engine ticks, submit ``per_tick``
    requests per tick with prompt lengths drawn (seeded) from
    ``[min_len, max_len]`` and ``max_new`` decode tokens each."""
    ticks: int
    per_tick: int = 1
    min_len: int = 4
    max_len: int = 7
    max_new: int = 6


# a scripted drift: short prompts (bucket 8), then long prompts (bucket 16)
# at higher arrival rate — shifts the bucket mix AND the decode/prefill ratio
DRIFT_SHORT_TO_LONG = (
    Phase(ticks=6, per_tick=1, min_len=4, max_len=7, max_new=6),
    Phase(ticks=8, per_tick=2, min_len=12, max_len=15, max_new=10),
)


class ScriptedTraffic:
    """Deterministic request schedule: ``schedule[t]`` is the list of
    (prompt, max_new_tokens) pairs submitted before tick ``t``.  The
    schedule is fully materialized from the seed at construction, so equal
    (phases, seed, vocab) always produce the identical byte stream."""

    def __init__(self, phases=DRIFT_SHORT_TO_LONG, *, seed: int = 0,
                 vocab: int = 200):
        rng = np.random.default_rng(seed)
        self.schedule: list[list[tuple[np.ndarray, int]]] = []
        for phase in phases:
            for _ in range(phase.ticks):
                tick_reqs = []
                for _ in range(phase.per_tick):
                    n = int(rng.integers(phase.min_len, phase.max_len + 1))
                    prompt = rng.integers(1, vocab, size=n).astype(np.int32)
                    tick_reqs.append((prompt, phase.max_new))
                self.schedule.append(tick_reqs)
        self.total_requests = sum(len(t) for t in self.schedule)

    def __len__(self) -> int:
        return len(self.schedule)


def check_conservation(engine) -> None:
    """submitted = finished_total + pending + active, in both stats views."""
    for view in (engine.stats(), engine.stats(window=8)):
        total = (view["requests_finished_total"] + view["requests_pending"]
                 + view["requests_active"])
        assert view["requests_submitted"] == total, (
            f"stats accounting leak: submitted={view['requests_submitted']} "
            f"!= finished_total={view['requests_finished_total']} + "
            f"pending={view['requests_pending']} + "
            f"active={view['requests_active']}")


def drive(engine, traffic: ScriptedTraffic, *, max_drain_ticks: int = 2000,
          check: bool = True) -> list:
    """Run the scripted traffic through ``engine``: submit each tick's
    requests, tick, then keep ticking until idle.  With ``check`` the
    conservation invariant is asserted after every tick and the no-drop /
    monotone-rid invariants on the final result.  Returns the finished
    requests sorted by rid."""
    submitted: list[int] = []
    for tick_reqs in traffic.schedule:
        for prompt, max_new in tick_reqs:
            rid = engine.submit(prompt, max_new_tokens=max_new)
            if submitted:
                assert rid > submitted[-1], "rids must be strictly increasing"
            submitted.append(rid)
        engine.step()
        if check:
            check_conservation(engine)
    drained = 0
    while engine.busy and drained < max_drain_ticks:
        engine.step()
        drained += 1
        if check:
            check_conservation(engine)
    assert not engine.busy, (
        f"engine still busy after {max_drain_ticks} drain ticks")
    done = sorted(engine.finished, key=lambda r: r.rid)
    if check:
        rids = [r.rid for r in done]
        assert len(set(rids)) == len(rids), f"duplicated requests: {rids}"
        dropped = sorted(set(submitted) - set(rids))
        # requests submitted before drive() was called finish too (the
        # engine is idle and conservation held every tick), so subset —
        # not equality — is the right no-drop check here
        assert not dropped, (
            f"dropped requests: submitted {submitted}, finished {rids}")
        assert all(r.done for r in done)
        assert all(len(r.generated) == r.max_new_tokens for r in done), \
            "every request must produce exactly its decode budget"
    return done


def streams(done) -> dict[int, tuple[int, ...]]:
    """rid -> generated token stream, for cross-engine comparison."""
    return {r.rid: tuple(r.generated) for r in done}


def assert_streams_equal(done_a, done_b) -> None:
    """Per-request token streams bit-identical between two runs (the
    hot-swap atomicity contract: swap vs. no-swap must be invisible)."""
    a, b = streams(done_a), streams(done_b)
    assert a.keys() == b.keys(), f"rid sets differ: {a.keys()} vs {b.keys()}"
    diff = {rid: (a[rid], b[rid]) for rid in a if a[rid] != b[rid]}
    assert not diff, f"token streams diverged: {diff}"
