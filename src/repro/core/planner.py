"""The paper's automatic loop-offload planner (§3.3, Fig. 2) — TPU-native,
extended to mixed offload destinations (Yamato, arXiv 2011.12431).

Pipeline, faithful to the paper with the FPGA->TPU substitutions of
DESIGN.md §2:

  Step 1  code analysis        — region census + jaxpr loop census
  Step 2  AI filter            — arithmetic intensity per region, keep top-a
  Step 3  resource filter      — cheap lowering of EVERY registered offload
                                 variant of each surviving region ->
                                 vmem fraction; efficiency = AI / fraction;
                                 rank (region, variant) pairs, keep the
                                 top-c regions (each with its variant
                                 ranking)
  Step 4  measured search      — a pluggable ``SearchStrategy``
                                 (core/strategies.py) proposes patterns
                                 ask–tell through a ``MeasurementLedger``;
                                 total measured patterns <= d, no pattern
                                 measured twice (baseline excluded, as in
                                 the paper where all-CPU is the pre-existing
                                 reference).  ``staged`` is the paper's
                                 3-round heuristic; ``genetic`` the
                                 companion papers' GA over mixed genomes;
                                 ``exhaustive`` the tiny-space oracle.
  Step 5  select               — fastest measured pattern; the selected
                                 mapping is the measurement's own structured
                                 ``Impl`` (no string re-parsing)

Because Step 3 ranks (region, variant) pairs rather than regions with one
pinned variant, the measured patterns may mix destinations across regions —
e.g. ``{fir_bank: pallas, fir_load: offload}`` — which is exactly the
mixed-offloading-destination extension of the follow-up paper.

Plans are cacheable: ``plan(..., cache=...)`` consults/updates a persistent
``PlanCache`` keyed by program name + abstract arg shapes/dtypes + variant
registry + backend + planner config, so an application is searched once per
placed hardware and then served from the cache with zero new measurements.

Defaults a=5, c=3, d=4 match the paper's evaluation conditions (§5.1.2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax

from repro.core import search
from repro.core.cost_model import CostModel
from repro.core.executor import (CompileCache, FaultPolicy,
                                 VerificationExecutor, VerifyJob,
                                 compile_key, measure_with_retry)
from repro.core.intensity import RegionAnalysis, analyze_region, count_loops
from repro.core.plan_cache import (PlanCache, measurement_cache_key,
                                   plan_cache_key, resolve_cache)
from repro.core.program import OffloadableProgram
from repro.core.regions import (BoundTuningSpace, Impl, offload_variants,
                                tuning_space)
from repro.core.resources import ResourceEstimate, precompile_many
from repro.core.search import Measurement, MeasurementLedger
from repro.core.strategies import SearchCandidate, SearchState, make_strategy


@dataclass(frozen=True)
class PlannerConfig:
    """Every knob of the automatic offload planner.

    All fields except ``reps``/``warmup`` participate in the plan-cache
    key; ``seed`` and the ``ga_*`` knobs participate only for strategies
    that read them (``genetic``/``surrogate``/``auto`` — they cannot change
    a staged or exhaustive trajectory).  See docs/plan-cache.md.

    Pipeline budgets (paper §5.1.2 defaults):

    * ``top_a`` (int, 5)            — Step-2 arithmetic-intensity filter
      width: regions kept after AI ranking.
    * ``top_c`` (int, 3)            — Step-3 resource-efficiency filter
      width: regions kept after (region, variant) ranking.
    * ``max_measurements`` (int, 4) — the paper's ``d``: Step-4 patterns
      that may consume real measurements (ledger hits are free).
    * ``resource_cap`` (float, 1.0) — summed VMEM fraction a combined
      pattern may claim; over-cap patterns are never built.
    * ``unroll_b`` (int, 1)         — kernel unroll knob (paper's ``b``).
    * ``tune_tiles`` (bool, False)  — widen the Step-4 genome from
      ``{region -> variant}`` to ``{region -> (variant, tile params)}``
      for variants that declared a ``TuningSpace`` at registration: the
      GA mutates/crosses tile points, staged adds a round-4 hill climb
      over the winner's tiles, exhaustive enumerates every valid point.
      Off (the default) reproduces the variant-only search bit-for-bit
      and keeps pre-tuning plan-cache keys unchanged.

    Measurement fidelity (NOT in the cache key — they change timing noise,
    never the search space):

    * ``warmup`` (int, 1) / ``reps`` (int, 5) — per-pattern timing runs;
      ``run_seconds`` is the median of ``reps``.

    Fault tolerance (also NOT in the cache key — they govern how the
    environment's failures are survived, never which pattern is best; see
    docs/fault-tolerance.md):

    * ``compile_timeout_s`` (float, 0.0) — wall ceiling per AOT compile
      under a watchdog; 0 disables.  Expiry is a transient
      ``CompileTimeout`` that gets a bounded retry.
    * ``run_timeout_s`` (float, 0.0) — wall ceiling per execution (first
      run, warmup, every timed rep); 0 disables.
    * ``max_retries`` (int, 2) / ``retry_backoff_s`` (float, 0.05) —
      bounded retry with exponential backoff for *transient* failures
      (timeouts, resource exhaustion, flaky devices).  Permanent failures
      (lowering errors, NaN/Inf output) never retry.
    * ``outlier_mad`` (float, 3.5) / ``remeasure`` (int, 2) — MAD-based
      outlier rejection over the timed reps: reps whose modified z-score
      exceeds the threshold are dropped, up to ``remeasure`` replacement
      reps run, and ``run_seconds`` is the median of the kept reps.
      ``outlier_mad=0`` disables.
    * ``quarantine_threshold`` (int, 2) — permanent failures strike the
      failed pattern's (region, variant[, tile]) genes; a gene with this
      many strikes is quarantined (strategies stop proposing it) and the
      strikes persist in the plan cache under ``measurement_key`` so
      future runs skip known-bad variants outright.

    Step-4 search strategy (core/strategies.py):

    * ``strategy`` (str, "staged")  — staged | genetic | surrogate |
      exhaustive | auto.
    * ``seed`` (int, 0)             — strategy RNG seed (GA determinism).
    * ``ga_population`` (int, 6)    — genomes per generation.
    * ``ga_generations`` (int, 4)   — generations (ledger hits free).
    * ``ga_crossover`` (float, 0.9) — uniform-crossover probability.
    * ``ga_mutation`` (float, 0.15) — per-gene mutation probability.
    * ``ga_tournament`` (int, 2)    — tournament size.
    * ``ga_elite`` (int, 1)         — elites carried over (re-measured
      free via the ledger).
    * ``ga_topk`` (int, 2)          — surrogate mode only: real
      measurements per generation; the rest of the population is scored
      by the roofline CostModel (core/cost_model.py).

    Verification executor (core/executor.py):

    * ``verify_workers`` (int, 1)   — thread-pool width for concurrent AOT
      compilation in Steps 3 and 4 (timed reps stay strictly serial at any
      width; the measured sequence and the selected pattern are identical
      for every value).  ``1`` is the fully serial pre-executor pipeline.
      Part of the plan-cache key so pipelined and serial plan provenance
      stay distinguishable.

    Example (the config is a frozen dataclass — derive variants with
    ``dataclasses.replace``):

    >>> from repro.core.planner import PlannerConfig
    >>> cfg = PlannerConfig(strategy="surrogate", max_measurements=8)
    >>> cfg.ga_topk
    2
    >>> import dataclasses
    >>> dataclasses.replace(cfg, ga_topk=3).ga_topk
    3
    >>> cfg.strategy
    'surrogate'
    """
    top_a: int = 5              # AI filter width (paper: 5)
    top_c: int = 3              # resource-efficiency filter width (paper: 3)
    max_measurements: int = 4   # d (paper: 4)
    resource_cap: float = 1.0   # summed vmem fraction cap for combinations
    unroll_b: int = 1           # kernel unroll knob (paper: 1)
    tune_tiles: bool = False    # search (variant, tile params) genes

    warmup: int = 1
    reps: int = 5
    # ---- fault tolerance (core/executor.py FaultPolicy; not in the key) ----
    compile_timeout_s: float = 0.0   # per-compile watchdog wall (0 = off)
    run_timeout_s: float = 0.0       # per-execution watchdog wall (0 = off)
    max_retries: int = 2             # bounded retries for transient failures
    retry_backoff_s: float = 0.05    # exponential-backoff base between tries
    outlier_mad: float = 3.5         # modified-z rep rejection (0 = off)
    remeasure: int = 2               # replacement reps after rejection
    quarantine_threshold: int = 2    # permanent-failure strikes per gene
    # ---- Step-4 search strategy (core/strategies.py) ----
    strategy: str = "staged"    # staged | genetic | surrogate | exhaustive | auto
    seed: int = 0               # strategy RNG seed (GA determinism)
    ga_population: int = 6      # genomes per generation
    ga_generations: int = 4     # generations (ledger hits don't spend d)
    ga_crossover: float = 0.9   # uniform-crossover probability
    ga_mutation: float = 0.15   # per-gene mutation probability
    ga_tournament: int = 2      # tournament size
    ga_elite: int = 1           # elites carried over (re-measured for free)
    ga_topk: int = 2            # surrogate: real measurements per generation
    # ---- verification executor (core/executor.py) ----
    verify_workers: int = 1     # concurrent AOT-compile threads (1 = serial)


def conditions_from_stats(stats: dict) -> dict:
    """Fold a ServeEngine windowed stats view (``engine.stats(window=N)``)
    into discrete measurement conditions for online replanning.

    The output is deliberately coarse — a plan-cache key ingredient
    (``OffloadableProgram.plan_extra``), not a telemetry dump: banding keeps
    neighboring windows of the same regime mapping to the same conditions
    (no key churn), while a real regime shift (dominant bucket, occupancy
    band, decode/prefill balance) re-opens the search.  Keys:

    * ``dominant_bucket`` — the prefill bucket with the most admissions in
      the window (ties favor the longer bucket; 0 when nothing admitted),
    * ``occupancy_band`` — mean slot occupancy in thirds: low / mid / high,
    * ``decode_prefill_band`` — ``floor(log2(1 + decode/prefill ratio))``,
      the workload-balance octave.

    Deterministic: equal stats give equal conditions."""
    hist = {int(b): int(c)
            for b, c in dict(stats.get("bucket_hist", {})).items()}
    dominant = (max(hist.items(), key=lambda kv: (kv[1], kv[0]))[0]
                if hist else 0)
    occ = float(stats.get("occupancy_mean", 0.0))
    occupancy_band = "low" if occ < 1 / 3 else ("mid" if occ < 2 / 3 else "high")
    ratio = max(float(stats.get("decode_prefill_ratio", 0.0)), 0.0)
    return {
        "dominant_bucket": dominant,
        "occupancy_band": occupancy_band,
        "decode_prefill_band": int(math.floor(math.log2(1.0 + ratio))),
    }


def _efficiency(analysis: RegionAnalysis,
                resources: ResourceEstimate | None) -> float:
    """The paper's resource efficiency: AI per unit of claimed resources.
    Single definition — both the ranking and the report read this."""
    if resources is None or not resources.lower_ok:
        return 0.0
    return analysis.arithmetic_intensity / max(
        resources.resource_fraction, 1e-6)


@dataclass
class VariantCandidate:
    """One (region, variant) offload destination candidate."""
    region: str
    variant: str
    analysis: RegionAnalysis
    resources: ResourceEstimate

    @property
    def efficiency(self) -> float:
        return _efficiency(self.analysis, self.resources)


@dataclass
class CandidateInfo:
    """Per-region analysis summary (Step 2 unit; Step 3 fans out to
    VariantCandidates, the best of which is mirrored here for reporting)."""
    region: str
    analysis: RegionAnalysis
    resources: ResourceEstimate | None = None      # best variant's estimate
    best_variant: str | None = None
    variant_estimates: dict[str, ResourceEstimate] = field(default_factory=dict)

    @property
    def efficiency(self) -> float:
        return _efficiency(self.analysis, self.resources)


@dataclass
class PlanReport:
    program: str
    source_loop_count: int
    jaxpr_loop_count: int
    candidates: list[CandidateInfo] = field(default_factory=list)
    ai_selected: list[str] = field(default_factory=list)       # after Step 2
    eff_selected: list[str] = field(default_factory=list)      # after Step 3
    eff_pairs: list[tuple[str, str]] = field(default_factory=list)
    baseline: Measurement | None = None
    measurements: list[Measurement] = field(default_factory=list)
    best_pattern: dict = field(default_factory=dict)
    speedup: float = 0.0
    best_seconds: float = 0.0          # winning measurement's own median
    skipped_combinations: list[str] = field(default_factory=list)
    from_cache: bool = False
    cache_key: str = ""
    strategy: str = "staged"           # which SearchStrategy produced this
    search_trace: list[dict] = field(default_factory=list)  # rounds/generations
    # cross-run measurement reuse: patterns served from plan-cache priming
    # (zero budget spent), and the size of the Step-3 survivor genome space
    # (what make_strategy("auto") keys its choice on)
    reused: list[Measurement] = field(default_factory=list)
    search_space: int = 0
    # pipelined-verification wall-clock accounting (core/executor.py):
    # verify_wall_s is the wall of the batched Step-4 verification phases
    # (compile + timed reps), compile_wall_s the portion the serial
    # pipeline was actually BLOCKED waiting on compiles — with workers > 1
    # it shrinks toward max-of-compiles per batch while the per-pattern
    # Measurement.compile_seconds (true compile durations) stay unchanged
    verify_workers: int = 1
    verify_wall_s: float = 0.0
    compile_wall_s: float = 0.0
    # the search's final CostModel calibration (export_state snapshot),
    # persisted next to the measurements so re-opened searches start from
    # calibrated deltas instead of the roofline seeds
    cost_model_state: dict = field(default_factory=dict)
    # fault-tolerance provenance: gene ids currently quarantined (filtered
    # from this search), and the full strike records persisted under
    # measurement_key so future runs skip known-bad variants
    quarantined: list[str] = field(default_factory=list)
    quarantine_records: list[dict] = field(default_factory=list)

    def best_impl(self) -> Impl:
        """The selected pattern as a dispatchable Impl."""
        return Impl(self.best_pattern)

    def summary(self) -> str:
        lines = [f"== offload plan: {self.program} =="
                 + ("  [served from plan cache]" if self.from_cache else "")]
        lines += [f"loops: source={self.source_loop_count} jaxpr={self.jaxpr_loop_count}",
                  f"search strategy: {self.strategy}",
                  f"AI top-a: {self.ai_selected}",
                  f"efficiency top-c: {self.eff_selected}"]
        if self.eff_pairs:
            lines.append("ranked destinations: "
                         + ", ".join(f"{r}={v}" for r, v in self.eff_pairs))
        for c in self.candidates:
            res = c.resources
            lines.append(
                f"  {c.region:18s} AI={c.analysis.arithmetic_intensity:10.2f} "
                f"flops={c.analysis.weighted_flops:.3e} "
                f"vmem_frac={res.resource_fraction if res else float('nan'):8.4f} "
                f"eff={c.efficiency:10.1f}"
                + (f" best_variant={c.best_variant}" if c.best_variant else ""))
        if self.baseline:
            lines.append(f"baseline (all-ref): {self.baseline.run_seconds*1e3:.2f} ms"
                         f"  (compile {self.baseline.compile_seconds*1e3:.0f} ms)")
        for m in self.measurements:
            lines.append(f"  pattern[{m.pattern}]: {m.run_seconds*1e3:.2f} ms"
                         f"  (compile {m.compile_seconds*1e3:.0f} ms)"
                         + (f"  [{m.attempts} attempts]" if m.attempts > 1 else "")
                         + ("" if m.ok else f"  FAILED [{m.failure_kind or '?'}]"
                            f" {m.error}"))
        if self.quarantined:
            lines.append("quarantined genes: " + ", ".join(self.quarantined))
        for m in self.reused:
            lines.append(f"  pattern[{m.pattern}]: {m.run_seconds*1e3:.2f} ms"
                         f"  [reused from plan cache, zero budget]")
        for t in self.search_trace:
            if "pairs" in t:          # cost-model pair-bias notes
                lines.append(f"  {t.get('stage', '?')}: " + "; ".join(
                    f"{'+'.join('='.join(g) for g in p['pair'])} "
                    f"{p['sign']} x{p['observations']} "
                    f"(mean {p['mean_rel_residual']:+.1%})"
                    for p in t["pairs"]))
                continue
            if "workers" in t:        # verification-executor accounting
                lines.append(
                    f"  {t.get('stage', '?')}: workers={t['workers']} "
                    f"batches={t.get('batches', 0)} "
                    f"compile_wall={t.get('compile_wall_s', 0.0)*1e3:.0f} ms "
                    f"(of {t.get('compile_seconds_total', 0.0)*1e3:.0f} ms "
                    f"compiled) verify_wall="
                    f"{t.get('verify_wall_s', 0.0)*1e3:.0f} ms "
                    f"cache_hits={t.get('compile_cache_hits', 0)}")
                continue
            # per-pattern timings are already listed above; the trace line
            # adds the stage grouping and the proposal count (which includes
            # free ledger hits, e.g. GA elites re-proposed across generations)
            n = len(t.get("patterns", []))
            line = (f"  {t.get('stage', '?')}: "
                    f"{n} proposal{'s' if n != 1 else ''}")
            if t.get("model_error") is not None:
                line += (f"  (surrogate error "
                         f"{t['model_error'] * 100:.1f}%)")
            lines.append(line)
        lines.append(f"best: {self.best_pattern}  speedup={self.speedup:.2f}x")
        return "\n".join(lines)


class AutoOffloader:
    def __init__(self, config: PlannerConfig = PlannerConfig(),
                 quarantine: "search.Quarantine | None" = None):
        self.config = config
        # offloader-lifetime compile memo: a pattern compiled once for a
        # (program, shapes) pair is never compiled again by this instance —
        # the cache-primed re-plan path (changed budget/strategy/variant
        # registry) re-verifies through warm executables
        self.compile_cache = CompileCache()
        # offloader-lifetime strike list.  An external instance may be
        # shared with a serving-side Replanner so a plan that faulted
        # mid-serve is filtered from every subsequent search; per-plan-run
        # records persisted in the cache merge into it on each plan().
        self.quarantine = (quarantine if quarantine is not None
                           else search.Quarantine(
                               threshold=config.quarantine_threshold))

    # ------------------------------------------------------------------
    def plan(self, program: OffloadableProgram,
             key: jax.Array | None = None,
             cache: "PlanCache | str | None" = None) -> PlanReport:
        """Plan ``program``: run the configured Step-4 search strategy, or
        serve the plan from ``cache``.

        Parameters
        ----------
        program:
            The ``OffloadableProgram`` to plan (regions + build + samples).
        key:
            PRNG key for ``program.sample_inputs`` (default
            ``jax.random.PRNGKey(0)``); does NOT affect the cache key.
        cache:
            A ``PlanCache``, a path, or None (no caching).  Three outcomes:

            * **hit** — an entry matches the full plan key (program shapes
              + variant registry + backend + config): the stored plan is
              returned with zero new measurements (``from_cache=True``);
            * **primed miss** — no plan-key match, but sibling entries
              measured under the same conditions (``measurement_cache_key``)
              donate their per-pattern measurements: the search re-runs,
              and every re-proposed known pattern is served from the
              ledger for free (``report.reused``);
            * **cold miss** — the full pipeline runs and the selection is
              stored (together with ALL its measurements) for both kinds
              of reuse above.

        Returns a ``PlanReport``; ``report.best_impl()`` is the
        dispatchable selected pattern.
        """
        store = resolve_cache(cache)
        ckey = plan_cache_key(program, self.config) if store is not None else ""
        if store is not None:
            entry = store.get(ckey)
            if entry is not None:
                return self._report_from_cache(program, ckey, entry)
        report = self._plan_measured(program, key, store=store)
        report.cache_key = ckey
        if store is not None and self._sound(report):
            store.put(ckey, self._cache_entry(report, program))
        return report

    @staticmethod
    def _sound(report: PlanReport) -> bool:
        """Only sound searches are worth freezing into the cache: a failed
        baseline or an all-patterns-failed round is likely transient (OOM,
        compile hiccup) and must be retried on the next plan() instead of
        being served forever.  An empty measurement list with a healthy
        baseline is legitimate (no destination fit the cap) and cacheable."""
        if report.baseline is None or not report.baseline.ok:
            return False
        if report.measurements and not any(m.ok for m in report.measurements):
            return False
        return True

    # ------------------------------------------------------------------
    def _plan_measured(self, program: OffloadableProgram,
                       key: jax.Array | None,
                       store: "PlanCache | None" = None) -> PlanReport:
        cfg = self.config
        key = key if key is not None else jax.random.PRNGKey(0)
        sample = program.sample_inputs(key)

        # ---- Step 1: code analysis ------------------------------------
        full_ref = program.build(Impl())
        try:
            jaxpr_loops = count_loops(full_ref, *sample)
        except Exception:  # noqa: BLE001 — census is advisory; a broken
            jaxpr_loops = 0  # all-ref build is recorded by the baseline
                             # measurement below, not raised out of plan()
        report = PlanReport(program=program.name,
                            source_loop_count=program.source_loop_count,
                            jaxpr_loop_count=jaxpr_loops)

        # ---- Step 2: arithmetic-intensity filter ----------------------
        cands: list[CandidateInfo] = []
        for r in program.regions:
            ana = analyze_region(r.analysis_fn, *r.analysis_args, name=r.name)
            cands.append(CandidateInfo(region=r.name, analysis=ana))
        report.candidates = cands
        by_ai = sorted(cands, key=lambda c: -c.analysis.arithmetic_intensity)
        ai_set = [c.region for c in by_ai[:cfg.top_a]]
        report.ai_selected = ai_set

        # ---- Step 3: resource filter over (region, variant) pairs -----
        # the cheap lowering of EVERY (region, variant) pair fans out on the
        # verification executor — with verify_workers > 1 the per-pair
        # ``precompile`` calls run concurrently (order-preserving, so the
        # ranking below is identical at any worker count)
        policy = FaultPolicy(compile_timeout_s=cfg.compile_timeout_s,
                             run_timeout_s=cfg.run_timeout_s,
                             max_retries=cfg.max_retries,
                             retry_backoff_s=cfg.retry_backoff_s,
                             outlier_mad=cfg.outlier_mad,
                             remeasure=cfg.remeasure)
        executor = VerificationExecutor(workers=cfg.verify_workers,
                                        cache=self.compile_cache,
                                        policy=policy)
        # known-bad genes: the offloader-lifetime strike list, topped up
        # with records persisted by previous runs under the same
        # measurement conditions
        quarantine = self.quarantine
        mkey = measurement_cache_key(program) if store is not None else ""
        if store is not None:
            quarantine.load_records(store.quarantine_for(mkey))
        try:
            region_map = {r.name: r for r in program.regions}
            pairs: list[VariantCandidate] = []
            lower_jobs: list[tuple] = []
            lower_meta: list[tuple] = []
            for c in cands:
                if c.region not in ai_set:
                    continue
                r = region_map[c.region]
                for var, fn in offload_variants(c.region).items():
                    lower_jobs.append((c.region, var, fn, r.analysis_args,
                                       r.static_kwargs))
                    lower_meta.append((c, var))
            for (c, var), est in zip(
                    lower_meta,
                    precompile_many(lower_jobs, mapper=executor.map_concurrent)):
                c.variant_estimates[var] = est
                pairs.append(VariantCandidate(c.region, var, c.analysis, est))
            eligible = [p for p in pairs if p.resources.lower_ok
                        and p.resources.resource_fraction <= cfg.resource_cap]
            # quarantined (region, variant) pairs never re-enter the
            # ranking: their past permanent failures already cost budget
            eligible = [p for p in eligible
                        if not quarantine.is_quarantined(p.region, p.variant)]

            def rank_key(p: VariantCandidate):
                # efficiency first; the region's declared deploy/measure
                # preference breaks ties (equal AI + equal fraction is common
                # for same-shaped variants)
                r = region_map[p.region]
                preferred = p.variant in (r.deploy_variant, r.measure_variant)
                return (-p.efficiency, 0 if preferred else 1, p.variant)

            ranked = sorted(eligible, key=rank_key)

            # per-region variant ranking; top-c regions by their best pair
            variants_of: dict[str, list[VariantCandidate]] = {}
            for p in ranked:
                variants_of.setdefault(p.region, []).append(p)
            eff_regions: list[str] = []
            for p in ranked:
                if p.region not in eff_regions:
                    eff_regions.append(p.region)
                if len(eff_regions) == cfg.top_c:
                    break
            report.eff_selected = eff_regions
            report.eff_pairs = [(p.region, p.variant) for p in ranked
                                if p.region in eff_regions]
            for c in cands:                         # mirror best pair for reports
                best = variants_of.get(c.region, [])
                if best:
                    c.best_variant = best[0].variant
                    c.resources = best[0].resources
                elif c.variant_estimates:           # all failed/over-cap: show one
                    c.resources = next(iter(c.variant_estimates.values()))

            # ---- Step 4: measured pattern search (pluggable strategy) -----
            # the all-ref baseline goes through the same fault policy as
            # every candidate: watchdogs when configured, bounded retry for
            # transients — an unlucky hiccup must not void the whole search
            report.baseline = measure_with_retry(
                lambda: (search.time_callable(
                    full_ref, sample, warmup=cfg.warmup, reps=cfg.reps,
                    pattern="all-ref", impl=Impl(),
                    compile_timeout_s=policy.compile_timeout_s,
                    run_timeout_s=policy.run_timeout_s,
                    check_finite=policy.check_finite,
                    outlier_mad=policy.outlier_mad,
                    remeasure=policy.remeasure), True),
                policy)

            def _job(impl) -> VerifyJob:
                impl = Impl(impl)
                return VerifyJob(key=compile_key(program.name, impl, sample),
                                 fn=program.build(impl), args=sample,
                                 pattern=impl.describe(), impl=dict(impl))

            def measure(impl: Impl) -> Measurement:
                return executor.measure_one(_job(impl), warmup=cfg.warmup,
                                            reps=cfg.reps)

            def measure_batch(impls: list) -> list:
                return executor.measure_batch([_job(i) for i in impls],
                                              warmup=cfg.warmup, reps=cfg.reps)

            def prefetch(impls: list) -> None:
                executor.prefetch([_job(i) for i in impls])

            ledger = MeasurementLedger(measure, budget=cfg.max_measurements,
                                       measure_batch_fn=measure_batch,
                                       prefetch_fn=prefetch,
                                       quarantine=quarantine)
            # cross-run reuse: sibling cache entries measured under the same
            # conditions donate their per-pattern measurements — a re-proposed
            # known pattern is served from the ledger and costs zero d
            primed: list[Measurement] = []
            if store is not None:
                for m in store.measurements_for(mkey):
                    impl = Impl(m.get("impl", {}))
                    pm = Measurement(
                        pattern=str(m.get("pattern", impl.describe())),
                        compile_seconds=float(m.get("compile_seconds", 0.0)),
                        run_seconds=float(m.get("run_seconds", float("inf"))),
                        runs=[], ok=bool(m.get("ok", False)),
                        error=str(m.get("error", "")), impl=dict(impl),
                        first_run_seconds=float(m.get("first_run_seconds", 0.0)))
                    ledger.prime(impl, pm)
                    primed.append(pm)
            # the all-ref baseline pre-exists (the paper's running CPU system):
            # a strategy re-proposing it gets the measurement without spending d.
            # Primed AFTER the cache donations so this run's fresh baseline wins.
            ledger.prime(Impl(), report.baseline)

            def _bound_tuning(p: VariantCandidate):
                # tile-parameter genes only when the config asks for them
                # AND the variant declared a space; None keeps the
                # variant-only trajectory bit-identical
                if not cfg.tune_tiles:
                    return None
                space = tuning_space(p.region, p.variant)
                if space is None:
                    return None
                return BoundTuningSpace(
                    space, tuple(region_map[p.region].analysis_args))

            state = SearchState(
                regions=eff_regions,
                ranked=[SearchCandidate(p.region, p.variant,
                                        p.resources.resource_fraction,
                                        p.efficiency,
                                        flops=p.analysis.flops,
                                        transcendentals=p.analysis.transcendentals,
                                        boundary_bytes=p.analysis.boundary_bytes,
                                        alignment=p.analysis.alignment,
                                        tuning=_bound_tuning(p))
                        for p in ranked if p.region in eff_regions],
                resource_cap=cfg.resource_cap,
                seed=cfg.seed,
                baseline=report.baseline,
                quarantine=quarantine)
            # the roofline surrogate, seeded from the Step-3 estimates and
            # pre-calibrated on everything already measured: the fresh baseline
            # (exact re-base), then the primed cross-run measurements —
            # single-gene patterns first, so their deltas are pinned exactly
            # before combined patterns distribute their residuals
            model = CostModel(candidates=state.ranked,
                              baseline_seconds=report.baseline.run_seconds
                              if report.baseline.ok else 0.0)
            # restore persisted calibration (deltas + pair-interaction
            # corrections) from sibling entries under the same measurement
            # conditions; this run's own observations below refine it
            if store is not None:
                model.load_state(store.cost_model_for(mkey))
            if report.baseline.ok:
                model.observe(Impl(), report.baseline.run_seconds)
            for m in sorted((p for p in primed if p.ok and p.mapping()),
                            key=lambda m: (len(m.mapping()), m.pattern)):
                model.observe(Impl(m.mapping()), m.run_seconds)
            state.cost_model = model

            # |non-ref genome space| of the survivors — make_strategy("auto")
            # picks exhaustive/staged/surrogate from this.  A variant with
            # a bound TuningSpace contributes every valid tile point (the
            # bare default is one of them); without tuning each variant
            # counts once, exactly as before.
            space = 1
            for r in eff_regions:
                n = 0
                for c in state.variants_of(r):
                    n += (max(c.tuning.size(), 1)
                          if c.tuning is not None else 1)
                space *= 1 + n
            report.search_space = max(space - 1, 0)
            strategy = make_strategy(cfg, space_size=report.search_space)
            strategy.run(state, ledger)
            executor.shutdown()     # sync final cache stats before reading them
            report.measurements = ledger.order       # budget-consuming, in order
            report.reused = [m for m in ledger.reused() if m.mapping()]
            report.quarantined = quarantine.blocked()
            report.quarantine_records = quarantine.to_records()
            report.strategy = strategy.name
            report.search_trace = state.trace
            report.skipped_combinations = state.skipped
            # cost-model residual-bias notes (ROADMAP "region interaction
            # terms"): pairs whose multi-gene observations stayed systematically
            # biased are surfaced so the surrogate's trust in composite
            # predictions is visible
            report.cost_model_state = model.export_state()
            bias = model.bias_notes()
            if bias:
                report.search_trace.append(
                    {"stage": "cost-model pair bias", "pairs": bias})
            # pipelined-verification wall-clock accounting
            stats = executor.stats.as_dict()
            report.search_trace.append({"stage": "verification executor",
                                        **stats})
            report.verify_workers = cfg.verify_workers
            report.verify_wall_s = stats["verify_wall_s"]
            report.compile_wall_s = stats["compile_wall_s"]

            # ---- Step 5: select -------------------------------------------
            # over everything the strategy was served this run: fresh
            # measurements AND cross-run primed patterns it re-proposed
            base_ok = report.baseline.ok
            ok_measurements = [m for m in ledger.served
                               if m.ok and m.mapping()]
            best = min(ok_measurements, key=lambda m: m.run_seconds,
                       default=None)
            if best is not None and (not base_ok
                                     or best.run_seconds < report.baseline.run_seconds):
                report.best_pattern = best.mapping()
                report.best_seconds = best.run_seconds
                # a failed baseline gives no meaningful reference: still select
                # the fastest working pattern, but never claim a speedup (and
                # _sound() keeps this search out of the plan cache)
                report.speedup = (report.baseline.run_seconds / best.run_seconds
                                  if base_ok else 1.0)
            else:
                report.best_pattern = {}
                report.best_seconds = (report.baseline.run_seconds
                                       if base_ok else 0.0)
                report.speedup = 1.0
            return report
        finally:
            # shutdown is idempotent; the finally guards the pool and the
            # offloader-lifetime CompileCache against ANY exception from
            # Step 3 onward — an aborted plan must neither leak worker
            # threads nor leave a transiently-failed compile future to be
            # served as permanent on the next plan()
            executor.shutdown()

    # ------------------------------------------------------------------
    def _report_from_cache(self, program: OffloadableProgram, ckey: str,
                           entry: dict) -> PlanReport:
        baseline_s = float(entry.get("baseline_seconds", 0.0))
        report = PlanReport(
            program=program.name,
            source_loop_count=program.source_loop_count,
            jaxpr_loop_count=int(entry.get("jaxpr_loop_count", 0)),
            best_pattern=dict(entry.get("best_pattern", {})),
            speedup=float(entry.get("speedup", 1.0)),
            best_seconds=float(entry.get("best_seconds", 0.0)),
            from_cache=True,
            cache_key=ckey,
            strategy=str(entry.get("strategy", "staged")),
            verify_workers=int(entry.get("verify_workers", 1)),
        )
        report.baseline = Measurement("all-ref", 0.0, baseline_s, [],
                                      impl={})
        return report

    @staticmethod
    def _cache_entry(report: PlanReport, program: OffloadableProgram) -> dict:
        baseline_s = report.baseline.run_seconds if report.baseline else 0.0
        # persist EVERY ok per-pattern measurement (fresh + reused), not just
        # the winner: sibling searches with the same measurement_key prime
        # their ledgers from these.  Failed measurements are deliberately
        # dropped — a compile hiccup must be retried, not remembered.
        persisted = [
            {
                "pattern": m.pattern,
                "impl": m.mapping(),
                "run_seconds": m.run_seconds,
                "compile_seconds": m.compile_seconds,
                "first_run_seconds": m.first_run_seconds,
                "ok": m.ok,
                "error": m.error,
            }
            for m in list(report.measurements) + list(report.reused)
            if m.ok and m.mapping()
        ]
        return {
            "measurement_key": measurement_cache_key(program),
            "measurements": persisted,
            # cumulative gene strike records (see search.Quarantine):
            # sibling searches under the same measurement_key load these and
            # skip known-bad variants without re-paying their failures
            "quarantine": list(report.quarantine_records),
            # the calibrated surrogate state, keyed with the measurements it
            # was learned from (see PlanCache.cost_model_for)
            "cost_model": dict(report.cost_model_state),
            "program": report.program,
            "backend": jax.default_backend(),
            "best_pattern": dict(report.best_pattern),
            "pattern": Impl(report.best_pattern).describe(),
            "speedup": report.speedup,
            "baseline_seconds": baseline_s,
            # the winning measurement's own median — NOT baseline/speedup,
            # which drifts by division and is wrong when the failed-baseline
            # path clamps speedup to 1.0
            "best_seconds": report.best_seconds,
            "strategy": report.strategy,
            "jaxpr_loop_count": report.jaxpr_loop_count,
            "measured_patterns": [m.pattern for m in report.measurements],
            # provenance of the verification pipeline that produced the plan
            "verify_workers": report.verify_workers,
            "verify_wall_s": report.verify_wall_s,
            "compile_wall_s": report.compile_wall_s,
        }
