"""End-to-end training driver: train a ~100M-param qwen2-family model for a
few hundred steps on CPU with the full production plumbing (sharded step,
checkpoints, restart, straggler watchdog, synthetic pipeline).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch qwen2-72b]
"""
import argparse
import dataclasses
import functools
import logging

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.optim.schedule import cosine_with_warmup
from repro.parallel.rules import ParallelismConfig
from repro.runtime.loop import LoopConfig, run_training

logging.basicConfig(level=logging.INFO,
                    format="%(asctime)s %(name)s %(message)s")


def hundred_m_config(arch: str):
    """Scale the assigned arch down to ~100M params, same family."""
    cfg = get_config(arch)
    return dataclasses.replace(
        cfg, name=cfg.name + "-100m",
        num_layers=min(cfg.num_layers, 12),
        d_model=512, num_heads=8,
        num_kv_heads=min(max(cfg.num_kv_heads, 1), 4) if cfg.num_kv_heads else 0,
        head_dim=64, d_ff=2560 if cfg.d_ff else 0, vocab_size=32_000,
        num_experts=min(cfg.num_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=512 if cfg.moe_d_ff else 0,
        dense_residual_d_ff=512 if cfg.dense_residual_d_ff else 0,
        rglru_d_rnn=512 if cfg.rglru_d_rnn else 0,
        attn_window=min(cfg.attn_window, 256) if cfg.attn_window else 0,
        encoder_layers=min(cfg.encoder_layers, 4),
        encoder_seq=min(cfg.encoder_seq, 128) if cfg.encoder_seq else 0,
        frontend_seq=min(cfg.frontend_seq, 64) if cfg.frontend_seq else 0,
        frontend_dim=512 if cfg.frontend_dim else 0,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="results/ckpt_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_config(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"active={cfg.active_param_count()/1e6:.1f}M")
    pcfg = ParallelismConfig(tp=True, fsdp=False, remat="none", microbatch=1)
    data = SyntheticLM(cfg, args.batch, args.seq, seed=0)
    ck = CheckpointManager(args.ckpt_dir, keep_n=2)
    lr = functools.partial(cosine_with_warmup, peak_lr=3e-3, warmup_steps=20,
                           total_steps=args.steps)
    res = run_training(cfg, pcfg, make_host_mesh(1, 1), data,
                       LoopConfig(total_steps=args.steps, checkpoint_every=100,
                                  log_every=20),
                       ckpt=ck, lr_fn=lr)
    print(f"loss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f} over "
          f"{res.final_step} steps "
          f"({'resumed from %d' % res.restored_from if res.restored_from else 'fresh'})")
    print(f"mean step time: {1e3*sum(res.step_times)/len(res.step_times):.0f} ms; "
          f"straggler events: {res.straggler_events}")


if __name__ == "__main__":
    main()
