"""Offloadable-program abstraction — what the planner plans over.

A program declares its *regions* (the paper's loop statements), how to build
a runnable callable for a chosen offload pattern (``Impl``), and sample
inputs (the paper's "sample processing specified by the application" used for
verification-environment measurement).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax

from repro.core.regions import Impl


@dataclass
class Region:
    """One offload candidate (paper: one loop statement)."""
    name: str
    analysis_fn: Callable            # the region's computation, traceable
    analysis_args: tuple             # ShapeDtypeStructs (full problem size)
    measure_variant: str = "offload"  # variant timed on this backend
    deploy_variant: str = "pallas"    # variant deployed on TPU (if registered)
    static_kwargs: dict = field(default_factory=dict)


@dataclass
class OffloadableProgram:
    """A whole application (paper: the C/C++ app given by the user)."""
    name: str
    regions: list[Region]
    build: Callable[[Impl], Callable]       # impl -> callable(*sample_args)
    sample_inputs: Callable[[jax.Array], tuple]   # rng key -> concrete args
    source_loop_count: int = 0               # loops in the original C source
    description: str = ""
