"""Roofline analysis over the dry-run JSONL (one row per (arch, shape, mesh)).

Terms (per assignment):
    compute    = HLO_FLOPs / (chips * 197 TF/s)
    memory     = HLO_bytes / (chips * 819 GB/s)
    collective = collective_bytes / (chips * 50 GB/s)

HLO_FLOPs / bytes come from the trip-count-attributed HLO analyzer (per
device; equivalent to global/chips).  MODEL_FLOPS = 6*N_active*tokens
(train) or 2*N_active*tokens (serve).  ``useful`` = MODEL_FLOPS time at peak
/ dominant term = the roofline fraction this report scores.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import SHAPES, get_config
from repro.launch.constants import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16)


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "hlo_cost" not in rec:
        return None
    chips = rec["devices"]
    hc = rec["hlo_cost"]
    compute = hc["flops"] / PEAK_FLOPS_BF16                  # per-device flops
    # memory term uses the fusion-optimistic byte model (see hlo_analysis);
    # hbm_bytes (zero-fusion upper bound) is reported alongside.
    memory = hc.get("hbm_fused", hc["hbm_bytes"]) / HBM_BW
    collective = hc["total_collective_bytes"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful_time = mf / (chips * PEAK_FLOPS_BF16)
    step_time = max(terms.values())
    hbm_gb = (rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]) / 1e9
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "memory_raw_s": hc["hbm_bytes"] / HBM_BW,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hc["flops"] * chips,
        "flops_ratio": mf / max(hc["flops"] * chips, 1.0),
        "roofline_fraction": useful_time / max(step_time, 1e-30),
        "hbm_gb_per_chip": hbm_gb,
        "step_time_s": step_time,
    }


def load_rows(path: str) -> list[dict]:
    rows = []
    seen = set()
    for line in open(path):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue
        key = (rec.get("arch"), rec.get("shape"), rec.get("mesh"))
        row = roofline_row(rec)
        if row is not None:
            if key in seen:           # keep the latest record per cell
                rows = [r for r in rows
                        if (r["arch"], r["shape"], r["mesh"]) != key]
            seen.add(key)
            rows.append(row)
    return rows


def format_table(rows: list[dict], mesh: str = "single") -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'bound':>10s} {'MF/HLO':>7s} {'roofline%':>9s} "
           f"{'HBM GB':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} {r['flops_ratio']:7.2f} "
            f"{100*r['roofline_fraction']:8.1f}% {r['hbm_gb_per_chip']:7.1f}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load_rows(args.inp)
    if args.csv:
        print("arch,shape,mesh,chips,compute_s,memory_s,collective_s,dominant,"
              "flops_ratio,roofline_fraction,hbm_gb_per_chip")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['mesh']},{r['chips']},"
                  f"{r['compute_s']:.6f},{r['memory_s']:.6f},"
                  f"{r['collective_s']:.6f},{r['dominant']},"
                  f"{r['flops_ratio']:.3f},{r['roofline_fraction']:.4f},"
                  f"{r['hbm_gb_per_chip']:.2f}")
    else:
        print(format_table(rows, args.mesh))


if __name__ == "__main__":
    main()
